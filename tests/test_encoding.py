"""Binary encoding + timestamp compression roundtrips."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.encoding import (Handle, IterPattern, RankPattern,
                                 decode_signature, decode_value,
                                 encode_signature, encode_value,
                                 read_uvarint, write_uvarint, zigzag,
                                 unzigzag)
from repro.core.timestamps import (TimestampBuffer, compress_timestamps,
                                   decompress_timestamps,
                                   delta_zigzag_decode, delta_zigzag_encode)

values = st.recursive(
    st.none() | st.booleans() | st.integers(-2**40, 2**40)
    | st.floats(allow_nan=False, allow_infinity=False) | st.text(max_size=20)
    | st.binary(max_size=20)
    | st.builds(Handle, st.integers(0, 1000))
    | st.builds(RankPattern, st.integers(-2**20, 2**20),
                st.integers(-2**20, 2**20)),
    lambda c: st.tuples(c, c) | st.builds(IterPattern, c, c),
    max_leaves=8)


@settings(max_examples=200, deadline=None)
@given(values)
def test_value_roundtrip(v):
    buf = bytearray()
    encode_value(buf, v)
    out, pos = decode_value(bytes(buf), 0)
    assert pos == len(buf)
    assert out == v


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**16), st.integers(0, 7), st.integers(0, 15),
       st.lists(values, max_size=5), values)
def test_signature_roundtrip(fid, tid, depth, args, ret):
    sig = encode_signature(fid, tid, depth, tuple(args), ret)
    f2, t2, d2, a2, r2 = decode_signature(sig)
    assert (f2, t2, d2, a2, r2) == (fid, tid, depth, tuple(args), ret)


@settings(max_examples=200, deadline=None)
@given(st.integers(-2**62, 2**62))
def test_zigzag(n):
    assert unzigzag(zigzag(n)) == n


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), max_size=40))
def test_uvarint(vals):
    buf = bytearray()
    for v in vals:
        write_uvarint(buf, v)
    pos = 0
    out = []
    for _ in vals:
        v, pos = read_uvarint(bytes(buf), pos)
        out.append(v)
    assert out == vals and pos == len(buf)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2**31), st.integers(0, 2**31)),
                max_size=100))
def test_timestamp_roundtrip(pairs):
    buf = TimestampBuffer()
    for a, b in pairs:
        buf.append(a, b)
    arr = buf.as_array()
    assert len(arr) == len(pairs)
    back = decompress_timestamps(compress_timestamps(arr))
    np.testing.assert_array_equal(back, arr)


def test_delta_zigzag_inverse():
    rng = np.random.RandomState(0)
    ticks = np.cumsum(rng.randint(0, 10000, size=(512, 2)).ravel()) \
        .astype(np.uint32).reshape(-1, 2)
    zz = delta_zigzag_encode(ticks)
    np.testing.assert_array_equal(delta_zigzag_decode(zz), ticks)
