"""Trainer fault tolerance + optimizer + checkpoint engine tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointEngine, latest_step, restore_sharded,
                              save_sharded)
from repro.configs import get_smoke_config
from repro.data import SyntheticConfig, synthetic_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                         ef_int8_compress, ef_int8_decompress)
from repro.train import StragglerDetector, Trainer, TrainerConfig

CFG = get_smoke_config("qwen1.5-0.5b").replace(loss_chunk=0)
# seeds pinned explicitly: batches are deterministic in (seed, step) and
# params in TrainerConfig.seed, so runs are bit-reproducible
DCFG = SyntheticConfig(vocab_size=CFG.vocab_size, seq_len=24, batch_size=4,
                       seed=0)


def _data(step):
    return synthetic_batch(DCFG, step)


def test_loss_decreases(tmp_path):
    tr = Trainer(CFG, TrainerConfig(num_steps=15, ckpt_dir=str(tmp_path),
                                    ckpt_every=0, seed=0),
                 AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=15),
                 data=_data)
    res = tr.run()
    assert res["final_step"] == 15
    # each step sees a fresh batch, so endpoint losses are noisy; compare
    # the mean of the last 3 against the mean of the first 3 instead
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_checkpoint_resume_continuity(tmp_path):
    kw = dict(ocfg=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
              data=_data)
    t1 = Trainer(CFG, TrainerConfig(num_steps=10, ckpt_dir=str(tmp_path),
                                    ckpt_every=5), **kw)
    t1.run()
    t2 = Trainer(CFG, TrainerConfig(num_steps=12, ckpt_dir=str(tmp_path),
                                    ckpt_every=5), **kw)
    t2.init_state()
    assert t2.start_step == 10
    # bit-identical state restore
    for a, b in zip(jax.tree.leaves(t1.state), jax.tree.leaves(t2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    res = t2.run()
    assert res["final_step"] == 12


def test_step_retry_and_restore_on_fault(tmp_path):
    calls = {"n": 0}

    def fault(step):
        if step == 7:
            calls["n"] += 1
            if calls["n"] <= 4:       # 2 retries + 2 after-restore retries
                raise RuntimeError("injected node failure")

    tr = Trainer(CFG, TrainerConfig(num_steps=9, ckpt_dir=str(tmp_path),
                                    ckpt_every=5, retry_max=1),
                 AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=9),
                 data=_data, fault_hook=fault)
    res = tr.run()
    assert res["final_step"] == 9
    assert calls["n"] >= 3            # retried, restored, retried again


def test_straggler_detector():
    det = StragglerDetector(z=3.0, warmup=5)
    for i in range(20):
        det.update(i, 0.1 + (0.001 * (i % 3)))
    assert det.update(20, 5.0) is True
    assert 20 in det.flagged
    assert det.update(21, 0.1) is False


def test_async_checkpoint(tmp_path):
    tr = Trainer(CFG, TrainerConfig(num_steps=6, ckpt_dir=str(tmp_path),
                                    ckpt_every=3, async_ckpt=True),
                 AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=6),
                 data=_data)
    res = tr.run()
    assert res["final_step"] == 6
    assert latest_step(str(tmp_path)) == 6


def test_keep_k_gc(tmp_path):
    eng = CheckpointEngine(str(tmp_path), keep=2)
    tree = {"a": np.arange(32, dtype=np.float32)}
    for s in (1, 2, 3, 4):
        eng.save(tree, s)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path))
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_corrupt_checkpoint_detected_and_skipped(tmp_path):
    eng = CheckpointEngine(str(tmp_path), keep=5)
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    eng.save(tree, 1)
    eng.save({"w": tree["w"] * 2}, 2)
    # corrupt the newest arrays.bin
    p = os.path.join(str(tmp_path), "step_00000002", "arrays.bin")
    with open(p, "r+b") as f:
        f.seek(8)
        f.write(b"\xde\xad\xbe\xef")
    restored = eng.restore_latest({"w": jax.ShapeDtypeStruct((8, 8),
                                                             np.float32)})
    assert restored is not None
    got, manifest = restored
    assert manifest["step"] == 1      # fell back to the older good ckpt
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_elastic_restore_n_to_m(tmp_path):
    """Checkpoint written by 4 simulated hosts restores on 2 and on 1."""
    tree = {"w": np.arange(128, dtype=np.float32).reshape(16, 8),
            "b": np.arange(8, dtype=np.float32)}

    class _SeqComm:
        rank, size = 0, 1
        def gather(self, x, root=0):
            return [x]
        def barrier(self):
            pass

    # sequential simulation: writer ranks first (no commit), rank 0 commits
    for r in (1, 2, 3):
        save_sharded(tree, str(tmp_path), 7, rank=r, nranks=4,
                     comm=_SeqComm(), commit=False)
    save_sharded(tree, str(tmp_path), 7, rank=0, nranks=4, comm=_SeqComm())
    path = os.path.join(str(tmp_path), "step_00000007")
    for nr in (1, 2):
        for r in range(nr):
            got, _ = restore_sharded(tree, path, rank=r, nranks=nr,
                                     verify=False)
            np.testing.assert_array_equal(got["w"], tree["w"])


def test_adamw_math():
    ocfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                       grad_clip=0.0, warmup_steps=0, total_steps=10,
                       min_lr_frac=1.0)
    params = {"w": jnp.ones((2, 2))}
    state = adamw_init(params)
    g = {"w": jnp.full((2, 2), 0.5)}
    new, m = adamw_update(ocfg, state, g)
    # first step: mhat = g, nhat = g^2 -> delta ~ sign(g)
    want = 1.0 - 0.1 * (0.5 / (0.5 + 1e-8))
    np.testing.assert_allclose(np.asarray(new["master"]["w"]), want,
                               rtol=1e-5)
    assert int(new["step"]) == 1


def test_cosine_lr_shape():
    ocfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                       min_lr_frac=0.1)
    assert float(cosine_lr(ocfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(cosine_lr(ocfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_lr(ocfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_ef_int8_error_feedback():
    rng = np.random.RandomState(0)
    g_true = jnp.asarray(rng.randn(64) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        q, scale, err = ef_int8_compress(g_true, err)
        acc = acc + ef_int8_decompress(q, scale)
    # error feedback: accumulated dequantized sum converges to 50*g
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=2e-5)


def test_grad_accum_matches_full_batch():
    """Accumulated microbatch GRADIENTS equal the full-batch gradient.
    (Post-AdamW states are not compared: the first-step update saturates to
    sign(g), so 1e-8 numerical noise near g=0 flips entries.)"""
    from repro.models import get_model
    model = get_model(CFG)
    batch = synthetic_batch(DCFG, 0)
    params = model.init_params(jax.random.PRNGKey(0))
    lossf = lambda p, b: model.loss_fn(p, b)[0]
    g_full = jax.grad(lossf)(params, batch)
    micro = jax.tree.map(lambda x: x.reshape((2, x.shape[0] // 2)
                                             + x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, params)
    for i in range(2):
        mb = jax.tree.map(lambda x: x[i], micro)
        g = jax.grad(lossf)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda g: g / 2, g_acc)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-5)
