"""Intra-process pattern tracker <-> decoder mirror property, and the
inter-process merge invariants."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.encoding import IterPattern, RankPattern
from repro.core.interprocess import _fit_component, merge_csts, dedupe_cfgs
from repro.core.patterns import IntraPatternDecoder, IntraPatternTracker
from repro.core.specs import REGISTRY
import repro.core.apis  # noqa: F401  (populate registry)


@settings(max_examples=150, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=0, max_size=60))
def test_tracker_decoder_mirror(offsets):
    """decode(encode(stream)) == stream for ANY offset sequence."""
    enc = IntraPatternTracker()
    dec = IntraPatternDecoder()
    key = ("f", 0)
    for off in offsets:
        encoded = enc.encode(key, (off,))
        out = dec.decode(key, encoded)
        assert out == [off]


@settings(max_examples=80, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 500), st.integers(2, 40))
def test_arithmetic_run_compresses_to_two(b, a, n):
    """i*a + b runs produce exactly two distinct encodings (concrete head +
    one IterPattern), which is what keeps the CST constant-size."""
    enc = IntraPatternTracker()
    outs = [tuple(enc.encode("k", (b + i * a,))) for i in range(n)]
    assert len(set(outs)) == 2
    assert outs[1] == (IterPattern(a, b),)


def test_multi_offset_joint_run():
    enc = IntraPatternTracker()
    dec = IntraPatternDecoder()
    for i in range(10):
        e = enc.encode("k", (i * 4, i * 100 + 7))
        assert dec.decode("k", e) == [i * 4, i * 100 + 7]


def test_fit_component():
    assert _fit_component([5, 5, 5]) == 5
    assert _fit_component([3, 7, 11, 15]) == RankPattern(4, 3)
    assert _fit_component([3, 7, 12]) is None
    assert _fit_component([1]) == 1


def test_dedupe_cfgs():
    res = dedupe_cfgs([b"A", b"B", b"A", b"A"])
    assert res.unique_cfgs == [b"A", b"B"]
    assert res.cfg_index == [0, 1, 0, 0]


def _sig(fid, args, ret=0):
    from repro.core.encoding import encode_signature
    return encode_signature(fid, 0, 0, args, ret)


def test_merge_rank_linear():
    """Paper Fig 3(c): per-rank offsets rank*a+b merge to one entry."""
    fid = REGISTRY.id_of("pwrite")
    nranks = 4
    csts = [[_sig(fid, (None, 64, r * 100))] for r in range(nranks)]
    merged = merge_csts(csts, REGISTRY)
    assert len(merged.merged_entries) == 1
    assert merged.n_rank_patterns == 1
    # every rank remaps its terminal 0 to merged terminal 0
    assert all(m[0] == 0 for m in merged.remaps)


def test_merge_respects_occurrence_index():
    """Two occurrences of the same masked signature on each rank must merge
    occurrence-by-occurrence, not cross-match."""
    fid = REGISTRY.id_of("pwrite")
    csts = [[_sig(fid, (None, 64, r * 10)), _sig(fid, (None, 64, 5000 + r * 10))]
            for r in range(3)]
    merged = merge_csts(csts, REGISTRY)
    assert len(merged.merged_entries) == 2


def test_merge_partial_rank_group_not_fitted():
    """Entries missing on some rank (collective-I/O aggregators) are kept
    per-rank rather than wrongly merged."""
    fid = REGISTRY.id_of("pwrite")
    csts = [[_sig(fid, (None, 64, 0))], [_sig(fid, (None, 64, 100))], []]
    merged = merge_csts(csts, REGISTRY)
    assert len(merged.merged_entries) == 2  # no fit without full coverage


def test_merge_no_inter_flag():
    fid = REGISTRY.id_of("pwrite")
    csts = [[_sig(fid, (None, 64, r * 100))] for r in range(4)]
    merged = merge_csts(csts, REGISTRY, inter_patterns=False)
    assert len(merged.merged_entries) == 4
