"""Distribution tests: run in a subprocess with 8 fake devices (jax pins the
device count at first init, so the main pytest process stays at 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src") + ":" + REPO)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=500)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_moe_ep_shard_map_matches_local():
    """Expert-parallel (all_to_all) MoE == local dispatch, numerically."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import get_model
        from repro.distributed.sharding import mesh_context
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("deepseek-moe-16b").replace(
            moe_capacity_factor=8.0)  # no drops -> exact expert math
        m = get_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                 cfg.vocab_size)
        batch = {"tokens": tok, "labels": tok}
        _, met_local = jax.jit(m.loss_fn)(params, batch)
        mesh = make_debug_mesh(2, 2, pod=2)
        with mesh_context(mesh):
            _, met_ep = jax.jit(m.loss_fn)(params, batch)
        # nll must match exactly (same routing, no drops); the aux
        # load-balance term is a nonlinear function of per-block means and
        # legitimately differs between global and per-shard routing stats
        d = abs(float(met_local["nll"]) - float(met_ep["nll"]))
        assert d < 3e-4, (float(met_local["nll"]), float(met_ep["nll"]))
        print("EP-vs-local OK", d)
    """)
    assert "EP-vs-local OK" in out


def test_train_step_sharded_matches_unsharded():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.shapes import ShapeSpec
        from repro.launch.steps import lower_cell, make_train_step
        from repro.optim import AdamWConfig, adamw_init
        from repro.models import get_model
        from repro.data import SyntheticConfig, synthetic_batch

        cfg = get_smoke_config("chatglm3-6b")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=4)
        model = get_model(cfg)
        dcfg = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               batch_size=8)
        batch = synthetic_batch(dcfg, 0)
        params = model.init_params(jax.random.PRNGKey(0))
        state = adamw_init(params)
        step = make_train_step(cfg, ocfg)
        ref_state, ref_m = step(jax.tree.map(jnp.copy, state), batch)

        mesh = make_debug_mesh(2, 2, pod=2)
        from repro.distributed.sharding import mesh_context
        with mesh_context(mesh):
            sh_state, sh_m = jax.jit(step)(state, batch)
        assert abs(float(ref_m["loss"]) - float(sh_m["loss"])) < 2e-3
        for a, b in zip(jax.tree.leaves(ref_state["master"]),
                        jax.tree.leaves(sh_state["master"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-3)
        print("sharded-train OK", float(sh_m["loss"]))
    """)
    assert "sharded-train OK" in out


def test_dryrun_cells_compile_on_debug_mesh():
    """lower+compile every step kind for three representative smoke archs."""
    out = _run("""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.shapes import ShapeSpec
        from repro.launch.steps import lower_cell
        from repro.launch import hlo_analysis

        mesh = make_debug_mesh(2, 2, pod=2)
        shapes = [ShapeSpec("t", 64, 8, "train"),
                  ShapeSpec("p", 64, 8, "prefill"),
                  ShapeSpec("d", 64, 8, "decode")]
        for arch in ("deepseek-v2-lite-16b", "hymba-1.5b",
                     "seamless-m4t-large-v2"):
            cfg = get_smoke_config(arch)
            for sh in shapes:
                lowered, _ = lower_cell(cfg, sh, mesh)
                c = lowered.compile()
                st = hlo_analysis.collective_stats(c.as_text())
                assert c.cost_analysis() is not None
        print("debug-mesh cells OK")
    """)
    assert "debug-mesh cells OK" in out


def test_compressed_crosspod_psum():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import shard_map
        from repro.optim.compress import compressed_psum_tree
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        g = {"w": jnp.arange(8.0).reshape(8, 1) * 1e-4}
        err = {"w": jnp.zeros((8, 1))}

        def f(g, e):
            return compressed_psum_tree(g, e, "pod")

        out, err2 = jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(P("pod", None), P("pod", None)),
            out_specs=(P("pod", None), P("pod", None))))(g["w"], err["w"])
        # per-pod average of the two shards, up to int8 quantization error
        # (half an lsb: amax/127/2 ~ 2.8e-6 for these magnitudes)
        want = (np.asarray(g["w"][:4]) + np.asarray(g["w"][4:])) / 2
        np.testing.assert_allclose(np.asarray(out)[:4], want, atol=6e-6)
        print("compressed psum OK")
    """)
    assert "compressed psum OK" in out
