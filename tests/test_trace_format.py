"""On-disk trace validation: missing files and unsupported format versions
fail with a clear TraceFormatError instead of raw KeyError/FileNotFoundError."""

import json
import os

import pytest

from repro.core.recorder import RecorderConfig, session
from repro.core.trace_format import (FORMAT_VERSION, TraceFormatError,
                                     read_trace_files)
from repro.core.apis import posix
from repro.core.reader import TraceReader


@pytest.fixture
def valid_trace(tmp_path):
    datadir = tmp_path / "data"
    datadir.mkdir()
    tracedir = str(tmp_path / "trace")
    with session(RecorderConfig(trace_dir=tracedir)):
        fd = posix.open(str(datadir / "f.bin"), os.O_RDWR | os.O_CREAT, 0o644)
        posix.pwrite(fd, b"x" * 16, 0)
        posix.close(fd)
    return tracedir


def test_missing_directory_is_a_format_error(tmp_path):
    with pytest.raises(TraceFormatError, match="missing"):
        read_trace_files(str(tmp_path / "nope"))


def test_missing_file_names_the_file(valid_trace):
    os.remove(os.path.join(valid_trace, "merged_cst.bin"))
    with pytest.raises(TraceFormatError, match="merged_cst.bin"):
        read_trace_files(valid_trace)
    with pytest.raises(TraceFormatError):
        TraceReader(valid_trace)


def test_unsupported_format_version(valid_trace):
    meta_path = os.path.join(valid_trace, "metadata.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["format_version"] = FORMAT_VERSION + 1
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(TraceFormatError, match="format_version"):
        read_trace_files(valid_trace)


def test_missing_format_version(valid_trace):
    meta_path = os.path.join(valid_trace, "metadata.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["format_version"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(TraceFormatError, match="format_version"):
        read_trace_files(valid_trace)


def test_malformed_metadata(valid_trace):
    with open(os.path.join(valid_trace, "metadata.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(TraceFormatError, match="metadata.json"):
        read_trace_files(valid_trace)


def test_valid_trace_reads(valid_trace):
    data = read_trace_files(valid_trace)
    assert data["meta"]["format_version"] == FORMAT_VERSION
    assert TraceReader(valid_trace).n_records(0) == 3
