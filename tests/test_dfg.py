"""Compressed-domain DFG observability: phase segmentation, cross-rank
divergence, and anomaly flagging straight from the grammar.

The properties under test --

  * :func:`dfg.grammar_digrams` (O(|grammar|), zero expansion) is
    edge-count-identical to a per-record directly-follows scan of the
    expanded stream, over random grammars and every ``synth_rank_states``
    shape; first/last boundary terminals are exact,
  * ``TraceView.digram_counts`` serves the grammar walk by default,
    matches the legacy expansion+histogram backends, and the cross-rank
    aggregate costs one walk per UNIQUE CFG (never per rank),
  * ``TraceView.dfg()`` node counts / edge weights equal a label-
    projected scan of the expanded stream,
  * phase boundaries are value-identical between stitched, merged, and
    ``refresh()``-folded reads (the fold walks only the delta-sized
    segment grammar, observable by monkeypatching the dfg walkers),
  * degraded (``ranks_present``-masked) traces still answer DFG/phase
    queries, carrying the PARTIAL-coverage warning and mask,
  * a structurally divergent rank is flagged by ``rank_divergence`` and
    surfaces as a ``dfg_divergent`` straggler reason end-to-end through
    ``TraceService``.
"""

import random
import tempfile
from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from benchmarks.workloads import synth_rank_states
from repro.core import dfg, faults, trace_format
from repro.core.comm import run_thread_world
from repro.core.faults import FaultPlan
from repro.core.interprocess import finalize_ranks, tree_finalize_ranks
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.sequitur import (Sequitur, concat_grammars, expand_grammar,
                                 parse_grammar, serialize_grammar)
from repro.core.specs import REGISTRY
from repro.traceserve import TraceService
import repro.core.apis  # noqa: F401  (populate registry)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.uninstall()


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _gen_calls(rng, n_calls, rank, nranks):
    fids = {name: REGISTRY.id_of(name)
            for name in ("open", "close", "pwrite", "lseek", "write")}
    fd = f"fd-{rank}"
    calls = [(fids["open"], ("/data/f.bin", 2, 438), fd)]
    for i in range(n_calls):
        kind = rng.random()
        if kind < 0.6:
            off = rank * 4096 + i * nranks * 4096
            calls.append((fids["pwrite"], (fd, b"x" * 4096, off), 4096))
        elif kind < 0.8:
            calls.append((fids["lseek"], (fd, rank * 256 + i * 256, 0),
                          rank * 256 + i * 256))
        else:
            calls.append((fids["write"], (fd, b"z" * 128), 128))
    calls.append((fids["close"], (fd,), 0))
    return calls


def _feed(rec, calls, tick_start=0):
    t = tick_start
    for fid, args, ret in calls:
        rec.record(fid, args, ret, 0, t, t + 1)
        t += 2
    return t


def _write_plain_trace(d, rank_calls):
    """Per-rank Recorder -> finalize_ranks -> one plain trace dir at ``d``."""
    states = []
    for r, calls in enumerate(rank_calls):
        rec = Recorder(rank=r, config=RecorderConfig())
        _feed(rec, calls)
        states.append(rec.local_state())
    merge, cfgs = finalize_ranks([s[0] for s in states],
                                 [s[1] for s in states], REGISTRY)
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgs.unique_cfgs,
                             cfg_index=cfgs.cfg_index,
                             rank_timestamps=[s[2] for s in states],
                             meta_extra={})
    return d


def _synth_trace(tmp, nranks, pattern, n_groups=4, n_calls=40, seed=0):
    csts, cfgs = synth_rank_states(nranks, n_groups=n_groups,
                                   n_calls=n_calls, pattern=pattern,
                                   seed=seed)
    merge, cfgres = tree_finalize_ranks(csts, cfgs, REGISTRY)
    d = f"{tmp}/synth_{pattern}"
    trace_format.write_trace(d, registry=REGISTRY,
                             merged_cst=merge.merged_entries,
                             unique_cfgs=cfgres.unique_cfgs,
                             cfg_index=cfgres.cfg_index,
                             rank_timestamps=[b""] * nranks, meta_extra={})
    return d


def _label_graph(g):
    """Order-independent normal form of a ``TraceView.dfg()`` result."""
    nodes = {(n["func"], n["pattern"]): n["count"] for n in g["nodes"]}
    lab = [(n["func"], n["pattern"]) for n in g["nodes"]]
    edges = Counter()
    for e in g["edges"]:
        edges[(lab[e["src"]], lab[e["dst"]])] += e["weight"]
    return nodes, dict(edges), g["n_records"]


# ---------------------------------------------------------------------------
# (a) grammar-derived DFG == brute-force per-record directly-follows scan
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_grammar_digrams_equal_record_scan_random_grammars(seed):
    rng = random.Random(seed)
    stream = []
    for _ in range(rng.randrange(1, 8)):
        block = [rng.randrange(6) for _ in range(rng.randrange(1, 5))]
        stream += block * rng.randrange(1, 12)
    g = Sequitur()
    for t in stream:
        g.push(t)
    rules = parse_grammar(g.serialize())
    expanded = list(expand_grammar(rules))
    assert expanded == stream  # lossless precondition
    edges, first, last = dfg.grammar_digrams(rules)
    assert edges == dfg.stream_digrams(stream)
    assert first == stream[0] and last == stream[-1]
    # episode record accounting covers the stream exactly
    eps = dfg.grammar_episodes(rules, lambda t: f"f{t}")
    assert sum(e[0] for e in eps) == len(stream)
    phases = dfg.phase_segments(eps)
    assert phases[0]["start"] == 0 and phases[-1]["end"] == len(stream)
    assert all(a["end"] == b["start"] for a, b in zip(phases, phases[1:]))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_fold_equals_concatenated_grammar(seed):
    """fold_digrams / fold_phases over two independently induced grammars
    equal one walk of ``concat_grammars`` -- the identity the incremental
    refresh path relies on."""
    rng = random.Random(seed)

    def mk(n):
        s = []
        for _ in range(rng.randrange(1, 5)):
            block = [rng.randrange(5) for _ in range(rng.randrange(1, 4))]
            s += block * rng.randrange(1, 9)
        g = Sequitur()
        for t in s[:n] or [0]:
            g.push(t)
        return parse_grammar(g.serialize())

    r1, r2 = mk(60), mk(60)
    n1 = len(list(expand_grammar(r1)))
    toff = 1000
    cat = parse_grammar(concat_grammars(
        [(serialize_grammar(r1), 0), (serialize_grammar(r2), toff)]))
    assert dfg.grammar_digrams(cat) == dfg.fold_digrams(
        dfg.grammar_digrams(r1), dfg.grammar_digrams(r2), toff)
    name = "f{}".format
    full = dfg.phase_segments(dfg.grammar_episodes(cat, lambda t: name(t)))
    folded = dfg.fold_phases(
        dfg.phase_segments(dfg.grammar_episodes(r1, lambda t: name(t))),
        dfg.phase_segments(dfg.grammar_episodes(
            r2, lambda t: name(t + toff))), n1)
    assert full == folded


@pytest.mark.parametrize("pattern", ["linear", "constant", "irregular",
                                     "nested", "multi", "mixed",
                                     "mixed_all"])
def test_digram_counts_identical_across_paths_synth_shapes(
        tmp_path, pattern):
    """Grammar-walk digram_counts == legacy expansion backend == brute
    scan, per rank AND cross-rank aggregated, for every synth shape."""
    d = _synth_trace(str(tmp_path), 5, pattern, seed=11)
    view = TraceReader(d).view()
    agg = {}
    for r in range(5):
        got = view.digram_counts(r)
        assert got == view.digram_counts(r, backend="numpy")
        brute = dfg.stream_digrams(
            expand_grammar(view.grammars[view.cfg_index[r]]))
        assert got == brute
        for k, c in got.items():
            agg[k] = agg.get(k, 0) + c
    assert view.digram_counts(rank=None) == agg
    assert view.digram_counts(rank=None, backend="numpy") == agg


def test_aggregate_costs_one_walk_per_unique_cfg(tmp_path, monkeypatch):
    """8 SPMD ranks share one unique CFG: the cross-rank aggregate, the
    label DFG, and rank_divergence together walk that grammar ONCE."""
    d = _synth_trace(str(tmp_path), 8, "linear", seed=2)
    view = TraceReader(d).view()
    assert len(view._cfg_mult) == 1  # precondition: CFG is shared
    walks = []
    real = dfg.grammar_digrams
    monkeypatch.setattr(dfg, "grammar_digrams",
                        lambda rules: (walks.append(len(rules)) or
                                      real(rules)))
    view.digram_counts(rank=None)
    view.dfg(rank=None)
    view.rank_divergence()
    assert len(walks) == 1


def test_dfg_nodes_edges_equal_label_projected_scan(tmp_path):
    rng = random.Random(17)
    nranks = 3
    d = _write_plain_trace(str(tmp_path), [
        _gen_calls(rng, 40, r, nranks) for r in range(nranks)])
    view = TraceReader(d).view()
    for r in range(nranks):
        g = view.dfg(rank=r)
        nodes, edges, n_rec = _label_graph(g)
        stream = [dfg.node_label(view._sigs[t]) for t in
                  expand_grammar(view.grammars[view.cfg_index[r]])]
        assert n_rec == len(stream) == view.n_records(r)
        assert nodes == dict(Counter(stream))
        assert edges == dfg.stream_digrams(stream)
    # the aggregate is the node/edge-wise sum over ranks
    tot_nodes, tot_edges = Counter(), Counter()
    for r in range(nranks):
        n, e, _ = _label_graph(view.dfg(rank=r))
        tot_nodes.update(n)
        tot_edges.update(e)
    an, ae, arec = _label_graph(view.dfg())
    assert an == dict(tot_nodes) and ae == dict(tot_edges)
    assert arec == view.total_records()


# ---------------------------------------------------------------------------
# (b) phase boundaries identical across stitched / merged / refresh-folded
# ---------------------------------------------------------------------------


def _drive_stream(sd, calls, bounds, finalize=True):
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = 0
    for i in range(len(bounds) - 1):
        t = _feed(rec, calls[bounds[i]:bounds[i + 1]], t)
        if i < len(bounds) - 2 or not finalize:
            rec.flush()
    if finalize:
        rec.finalize()
    return rec, t


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_phases_and_dfg_identical_stitched_vs_merged(seed):
    with tempfile.TemporaryDirectory(prefix="dfg_modes") as tmp:
        sd = f"{tmp}/s"
        rng = random.Random(seed)
        calls = _gen_calls(rng, rng.randrange(20, 70), 0, 1)
        k = len(calls)
        bounds = sorted({0, rng.randrange(1, k), rng.randrange(1, k), k})
        _drive_stream(sd, calls, bounds)
        stitched = TraceReader(sd, mode="stitched").view()
        merged = TraceReader(sd, mode="merged").view()
        assert stitched.phases(0) == merged.phases(0)
        assert _label_graph(stitched.dfg(0)) == _label_graph(merged.dfg(0))
        assert (stitched.rank_divergence()["per_rank"]
                == merged.rank_divergence()["per_rank"])


def test_refresh_folded_phases_identical_and_walks_only_delta(
        tmp_path, monkeypatch):
    """A live stitched reader folds committed epochs one at a time: the
    folded view's phases/DFG equal a from-scratch stitched read, and the
    fold walks ONLY the new segment's (delta-sized) grammar -- queries on
    the refreshed view hit the seeded memos with zero further walks."""
    sd = str(tmp_path / "s")
    calls = _gen_calls(random.Random(23), 80, 0, 1)
    bounds = [0, 25, 50, len(calls)]
    rec = Recorder(rank=0, config=RecorderConfig(trace_dir=sd))
    t = _feed(rec, calls[bounds[0]:bounds[1]])
    rec.flush()

    reader = TraceReader(sd, mode="stitched")
    view = reader.view()
    # warm the DFG + phase memos so the fold must carry them forward
    before_phases = view.phases(0)
    view.digram_counts(0)
    full_size = len(reader.unique_cfgs[reader.cfg_index[0]])

    digram_walks, episode_walks = [], []
    real_gd, real_ge = dfg.grammar_digrams, dfg.grammar_episodes
    monkeypatch.setattr(dfg, "grammar_digrams",
                        lambda rules: (digram_walks.append(len(rules)) or
                                      real_gd(rules)))
    monkeypatch.setattr(
        dfg, "grammar_episodes",
        lambda rules, name_of: (episode_walks.append(len(rules)) or
                               real_ge(rules, name_of)))

    for i in range(1, len(bounds) - 1):
        t = _feed(rec, calls[bounds[i]:bounds[i + 1]], t)
        rec.flush()
        digram_walks.clear()
        episode_walks.clear()
        assert reader.refresh() == 1
        # the fold walked exactly one grammar: the new segment's
        assert len(digram_walks) == 1 and len(episode_walks) == 1
        seg_data, err = trace_format.load_segment(
            sd, trace_format.read_manifest(sd)["segments"][i])
        assert err is None
        seg_size = len(parse_grammar(seg_data["unique_cfgs"][0]))
        assert digram_walks == [seg_size] and episode_walks == [seg_size]
        view = reader.view()
        fresh = TraceReader(sd, mode="stitched").view()
        fresh_phases = fresh.phases(0)
        fresh_digrams = fresh.digram_counts(0)
        fresh_graph = _label_graph(fresh.dfg(0))
        digram_walks.clear()
        episode_walks.clear()
        assert view.phases(0) == fresh_phases
        assert view.digram_counts(0) == fresh_digrams
        assert _label_graph(view.dfg(0)) == fresh_graph
        # refreshed-view queries were answered from the seeded memos
        assert digram_walks == [] and episode_walks == []
        assert view.phases(0)[0]["start_record"] == 0
    assert view.phases(0) != before_phases  # history actually grew


def test_phase_segmentation_reads_like_the_program(tmp_path):
    """Deterministic shape: write-loop, then metadata loop, then a read
    loop -- phases cut at the structure shifts with exact record ranges
    and meaningful labels."""
    fids = {n: REGISTRY.id_of(n)
            for n in ("open", "close", "pwrite", "lseek", "pread")}
    fd = "fd-0"
    calls = [(fids["open"], ("/data/a.bin", 2, 438), fd)]
    calls += [(fids["pwrite"], (fd, b"x" * 512, 512 * i), 512)
              for i in range(40)]
    calls += [(fids["lseek"], (fd, 64 * i, 0), 64 * i) for i in range(30)]
    calls += [(fids["pread"], (fd, 512, 512 * i), 512) for i in range(40)]
    calls.append((fids["close"], (fd,), 0))
    d = _write_plain_trace(str(tmp_path), [calls])
    view = TraceReader(d).view()
    ph = view.phases(0)
    assert ph[0]["start_record"] == 0
    assert ph[-1]["end_record"] == len(calls)
    labels = [p["label"] for p in ph]
    doms = [set(p["dominant_funcs"]) for p in ph]
    assert {"pwrite"} in doms and {"lseek"} in doms and {"pread"} in doms
    i_w = doms.index({"pwrite"})
    i_m = doms.index({"lseek"})
    i_r = doms.index({"pread"})
    assert i_w < i_m < i_r  # temporal order preserved
    assert labels[i_w].startswith("write")
    assert labels[i_m].startswith("metadata")
    assert labels[i_r].startswith("read")
    # record accounting: the 40-write run lives inside the write phase
    assert ph[i_w]["end_record"] - ph[i_w]["start_record"] >= 40


# ---------------------------------------------------------------------------
# (c) degraded traces: DFG/phase queries carry the PARTIAL warning
# ---------------------------------------------------------------------------


def test_degraded_trace_dfg_queries_carry_partial_warning(tmp_path):
    root = tmp_path / "runs"
    sd = str(root / "job")
    nranks, dead = 4, 1
    first = [_gen_calls(random.Random(70 + r), 10, r, nranks)
             for r in range(nranks)]
    extra = [_gen_calls(random.Random(80 + r), 6, r, nranks)
             for r in range(nranks)]

    def worker(comm, rank):
        rec = Recorder(rank=rank, config=RecorderConfig(
            trace_dir=sd, flush_timeout_s=2.0))
        t = _feed(rec, first[rank])
        rec.flush(comm)
        comm.barrier()
        if rank == 0:
            faults.install(FaultPlan(dead_ranks=(dead,)))
        comm.barrier()
        _feed(rec, extra[rank], t)
        rec.flush(comm)  # degraded commit: `dead` never shows up
        return None

    run_thread_world(nranks, worker)
    faults.uninstall()

    with pytest.warns(RuntimeWarning, match="PARTIAL"):
        view = TraceReader(sd, mode="stitched").view()
    # the queries still answer, exactly over the records present
    assert view.dfg()["n_records"] == view.total_records()
    assert view.phases(dead)[-1]["end_record"] == view.n_records(dead)
    assert view.rank_divergence()["nranks"] == nranks

    with TraceService(str(root), mode="stitched",
                      max_staleness_s=0.0) as svc:
        for fam in ("dfg", "phases", "anomalies"):
            res = svc.query("job", fam)
            assert res.coverage["complete"] is False, fam
            assert res.coverage["ranks_partial"] == [dead], fam
        rep = svc.stragglers("job")
        assert "partial_coverage" in rep["reasons"][dead]


# ---------------------------------------------------------------------------
# cross-rank divergence: the structurally odd rank is flagged, with reason
# ---------------------------------------------------------------------------


def _divergent_world_calls(nranks, odd, n=40):
    fids = {name: REGISTRY.id_of(name)
            for name in ("open", "close", "pwrite", "lseek")}
    rank_calls = []
    for r in range(nranks):
        fd = f"fd-{r}"
        calls = [(fids["open"], ("/data/f.bin", 2, 438), fd)]
        if r == odd:
            # metadata churn: seek-seek-write where everyone else streams
            for i in range(n):
                calls.append((fids["lseek"], (fd, 64 * i, 0), 64 * i))
                calls.append((fids["lseek"], (fd, 64 * i + 8, 0),
                              64 * i + 8))
                if i % 4 == 0:
                    calls.append((fids["pwrite"],
                                  (fd, b"x" * 64, 64 * i), 64))
        else:
            base = r * 4096
            for i in range(n):
                calls.append((fids["pwrite"],
                              (fd, b"x" * 4096, base + i * nranks * 4096),
                              4096))
        calls.append((fids["close"], (fd,), 0))
        rank_calls.append(calls)
    return rank_calls


def test_divergent_rank_flagged_with_reason(tmp_path):
    root = tmp_path / "runs"
    nranks, odd = 6, 4
    sd = _write_plain_trace(str(root / "job"),
                            _divergent_world_calls(nranks, odd))
    view = TraceReader(sd).view()
    rep = view.rank_divergence(threshold=0.25)
    assert rep["divergent"] == [odd]
    assert rep["majority_size"] == nranks - 1
    assert rep["per_rank"][odd] > 0.25
    assert all(d_ == 0.0 for r, d_ in enumerate(rep["per_rank"])
               if r != odd)

    with TraceService(str(root), max_staleness_s=0.0) as svc:
        anom = svc.query("job", "anomalies")
        assert anom.value["divergent"] == [odd]
        rep = svc.stragglers("job")
        assert odd in rep["stragglers"]
        assert "dfg_divergent" in rep["reasons"][odd]
        assert rep["dfg_divergent"] == [odd]
        # memoized per generation: the repeat is a dictionary hit
        again = svc.query("job", "anomalies")
        assert again.cached and again.value == anom.value
