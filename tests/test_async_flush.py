"""Async background flush (the non-blocking Recorder.flush): byte-identity
with sync flushes, fault injection into the background committer,
coalescing of overlapping flush requests, drain-on-finalize, the true
point-to-point / collective-exchange reduce transports, and the lockstep
cadence vote."""

import os
import random
import shutil
import tempfile
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: seeded-random example generation
    from _hypothesis_compat import given, settings, strategies as st

from test_streaming import _feed, _gen_calls, _split

from repro.core import streaming
from repro.core.comm import (Comm, SoloComm, reduce_rounds,
                             reduce_tree_via_exchange, run_thread_world)
from repro.core.reader import TraceReader
from repro.core.recorder import Recorder, RecorderConfig
from repro.core.specs import REGISTRY
import repro.core.apis  # noqa: F401  (populate registry)


def _dir_snapshot(root):
    """{relative path: bytes} of every file under a trace directory."""
    out = {}
    for dirpath, _dirs, files in os.walk(root):
        for f in files:
            p = os.path.join(dirpath, f)
            with open(p, "rb") as fh:
                out[os.path.relpath(p, root)] = fh.read()
    return out


# ---------------------------------------------------------------------------
# async == sync byte identity (the tentpole property)
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1),
       st.integers(min_value=1, max_value=3))
def test_async_trace_byte_identical_solo(seed, n_flushes):
    """A drained async run writes the byte-identical trace directory to a
    sync run of the same calls: only WHERE the commit runs moves."""
    rng = random.Random(seed)
    calls = _gen_calls(rng, 40, 0, 1)
    bounds = sorted(rng.sample(range(1, len(calls)), n_flushes))
    tmp = tempfile.mkdtemp(prefix="async_ident_")
    try:
        snaps = {}
        for mode in ("sync", "async"):
            td = os.path.join(tmp, mode)
            rec = Recorder(config=RecorderConfig(
                trace_dir=td, async_flush=(mode == "async")))
            t = 0
            for i, part in enumerate(_split(calls, bounds)):
                t = _feed(rec, part, t)
                if i < n_flushes:
                    rec.flush()
                    rec.drain()  # no coalescing: epochs stay 1:1 with sync
            rec.finalize()
            snaps[mode] = _dir_snapshot(td)
        assert snaps["sync"] == snaps["async"]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def test_async_trace_byte_identical_threadcomm(tmp_path):
    """Multi-rank async flushes (lockstep vote + dup'd background comm)
    still produce the byte-identical directory to the sync collective."""
    nranks = 4
    rank_calls = [_gen_calls(random.Random(100 + r), 30, r, nranks)
                  for r in range(nranks)]
    snaps = {}
    for mode in ("sync", "async"):
        td = str(tmp_path / mode)

        def worker(comm, rank, td=td, async_=(mode == "async")):
            rec = Recorder(rank=rank, config=RecorderConfig(
                trace_dir=td, async_flush=async_))
            t = 0
            for i, part in enumerate(_split(rank_calls[rank], [10, 20])):
                t = _feed(rec, part, t)
                if i < 2:
                    rec.flush(comm)
                    rec.drain()
            return rec.finalize(comm)

        stats = run_thread_world(nranks, worker)
        assert stats[0] is not None and stats[0].epochs == 3
        snaps[mode] = _dir_snapshot(td)
    assert snaps["sync"] == snaps["async"]


# ---------------------------------------------------------------------------
# fault injection: the background committer fails / stalls
# ---------------------------------------------------------------------------


def test_async_error_surfaces_on_drain_then_recovers(tmp_path, monkeypatch):
    td = str(tmp_path / "t")
    rec = Recorder(config=RecorderConfig(trace_dir=td, async_flush=True))
    _feed(rec, _gen_calls(random.Random(0), 10, 0, 1))
    boom = OSError("trace volume gone")

    def bad_run_flush(*a, **k):
        raise boom

    monkeypatch.setattr(streaming, "run_flush", bad_run_flush)
    rec.flush()  # submits; must NOT raise here
    with pytest.raises(RuntimeError) as ei:
        rec.drain()
    assert ei.value.__cause__ is boom
    # the error is consumed exactly once; the recorder stays usable
    monkeypatch.undo()
    _feed(rec, _gen_calls(random.Random(1), 8, 0, 1), tick_start=10 ** 6)
    rec.flush()
    rec.drain()
    stats = rec.finalize()
    assert stats is not None and stats.epochs >= 1
    assert TraceReader(td, mode="stitched").nranks == 1


def test_async_error_surfaces_on_finalize(tmp_path, monkeypatch):
    """A failed background commit must surface from finalize, not vanish."""
    td = str(tmp_path / "t")
    rec = Recorder(config=RecorderConfig(trace_dir=td, async_flush=True))
    _feed(rec, _gen_calls(random.Random(3), 10, 0, 1))
    monkeypatch.setattr(streaming, "run_flush",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("mid-commit failure")))
    rec.flush()
    with pytest.raises(RuntimeError, match="background epoch commit failed"):
        rec.finalize()


def test_overlapping_flushes_coalesce(tmp_path, monkeypatch):
    """flush() while an epoch is in flight coalesces (at-most-one in
    flight); the coalesced records ride the next committed epoch and no
    record is lost."""
    td = str(tmp_path / "t")
    gate = threading.Event()
    started = threading.Event()
    real = streaming.run_flush

    def slow_run_flush(*a, **k):
        started.set()
        assert gate.wait(30)
        return real(*a, **k)

    monkeypatch.setattr(streaming, "run_flush", slow_run_flush)
    rec = Recorder(config=RecorderConfig(trace_dir=td, async_flush=True))
    calls = _gen_calls(random.Random(2), 30, 0, 1)
    t = _feed(rec, calls[:10])
    rec.flush()
    assert started.wait(30)
    t = _feed(rec, calls[10:20], t)
    rec.flush()  # epoch 0 still committing -> coalesce
    rec.flush()  # still in flight -> coalesce again
    assert rec.epochs_coalesced == 2
    assert rec.epoch == 1  # only one epoch was snapshotted
    gate.set()
    rec.drain()
    _feed(rec, calls[20:], t)
    stats = rec.finalize()  # tail flush carries the coalesced records
    assert stats.n_records == len(calls)
    reader = TraceReader(td, mode="stitched")
    assert reader.n_records(0) == len(calls)


def test_finalize_during_inflight_drains(tmp_path, monkeypatch):
    """finalize() during an in-flight commit waits for it, tail-flushes,
    and the resulting trace is complete."""
    td = str(tmp_path / "t")
    real = streaming.run_flush

    def slow_run_flush(*a, **k):
        time.sleep(0.3)
        return real(*a, **k)

    monkeypatch.setattr(streaming, "run_flush", slow_run_flush)
    rec = Recorder(config=RecorderConfig(trace_dir=td, async_flush=True))
    calls = _gen_calls(random.Random(7), 24, 0, 1)
    t = _feed(rec, calls[:12])
    rec.flush()  # in flight for >= 0.3s
    _feed(rec, calls[12:], t)
    stats = rec.finalize()
    assert stats.epochs == 2
    reader = TraceReader(td, mode="stitched")
    assert reader.n_records(0) == len(calls)
    from repro.core import trace_format
    manifest = trace_format.read_manifest(td)
    assert len(manifest["segments"]) == 2 and "merged" in manifest


# ---------------------------------------------------------------------------
# transports: true p2p schedule, collective exchange, cadence vote
# ---------------------------------------------------------------------------


def _reference_fold(size, fn, leaf):
    items = [leaf(r) for r in range(size)]
    while len(items) > 1:
        items = [fn(items[i], items[i + 1]) if i + 1 < len(items)
                 else items[i] for i in range(0, len(items), 2)]
    return items[0]


def test_threadcomm_p2p_reduce_matches_reference():
    """ThreadComm's send/recv log-round schedule folds in the identical
    association order as the gather fallback (string concat is
    association-sensitive, so any divergence shows)."""
    def worker(comm, rank):
        return comm.reduce_tree(f"[{rank}]", lambda a, b: a + b)

    for size in (2, 3, 5, 8):
        res = run_thread_world(size, worker)
        assert res[0] == _reference_fold(size, lambda a, b: a + b,
                                         lambda r: f"[{r}]")
        assert all(r is None for r in res[1:])


def test_threadcomm_send_recv_fifo():
    def worker(comm, rank):
        if rank == 0:
            comm.send("a", 1)
            comm.send("b", 1)
            return None
        return comm.recv(0), comm.recv(0)

    assert run_thread_world(2, worker)[1] == ("a", "b")


def test_reduce_rounds_cover_all_ranks_once():
    for size in (1, 2, 3, 5, 8, 13, 16):
        rounds = reduce_rounds(size)
        senders = [src for perm in rounds for src, _ in perm]
        assert sorted(senders) == list(range(1, size))  # everyone ships once
        for perm in rounds:
            assert all(dst < src for src, dst in perm)


def test_reduce_tree_via_exchange_matches_reference():
    """The SPMD collective-exchange variant (what JaxComm runs over the
    ppermute byte transport) folds identically to the reference."""
    for size in (1, 2, 3, 5, 8):
        payloads = [None] * size
        barrier = threading.Barrier(size)

        def make_exchange(rank):
            def exchange(payload, perm):
                payloads[rank] = payload
                barrier.wait()
                got = next((payloads[src] for src, dst in perm
                            if dst == rank), None)
                barrier.wait()
                return got
            return exchange

        results = [None] * size

        def worker(r):
            results[r] = reduce_tree_via_exchange(
                r, size, f"[{r}]", lambda a, b: a + b, make_exchange(r))

        threads = [threading.Thread(target=worker, args=(r,))
                   for r in range(size)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert results[0] == _reference_fold(size, lambda a, b: a + b,
                                             lambda r: f"[{r}]")
        assert all(r is None for r in results[1:])


def test_pack_bytes_array_roundtrip():
    from repro.distributed.sharding import (pack_bytes_array,
                                            unpack_bytes_array)
    for payload in (None, b"", b"x", b"hello world" * 10):
        n = 0 if payload is None else len(payload)
        arr = pack_bytes_array(payload, n + 5 + 3)
        assert arr.dtype == np.uint8 and arr.shape == (n + 8,)
        assert unpack_bytes_array(arr) == payload
    with pytest.raises(ValueError):
        pack_bytes_array(b"xxxx", 5)  # cannot hold payload + header


def test_vote_any_threadcomm():
    def worker(comm, rank):
        return comm.vote_any(rank == 2), comm.vote_any(False)

    assert run_thread_world(4, worker) == [(True, False)] * 4
    assert SoloComm().vote_any(True) is True
    assert SoloComm().vote_any(False) is False


def test_maybe_flush_lockstep(tmp_path):
    """The cadence vote: one rank hitting its flush threshold makes EVERY
    rank flush (non-SPMD record counts stay in lockstep); a vote with
    nobody due is a cheap no-op everywhere."""
    td = str(tmp_path / "t")
    fid = REGISTRY.id_of("write")
    nranks = 3

    def worker(comm, rank):
        rec = Recorder(rank=rank, comm=comm, config=RecorderConfig(
            trace_dir=td, flush_every_n_records=20))
        n = 25 if rank == 0 else 5  # only rank 0 crosses the threshold
        for i in range(n):
            rec.record(fid, (f"fd{rank}", b"x" * 8), 8, 0, 2 * i, 2 * i + 1)
        rec.maybe_flush(comm)
        after_first = rec.epoch
        rec.maybe_flush(comm)  # nobody due now -> no-op on every rank
        assert rec.epoch == after_first
        rec.finalize(comm)
        return after_first

    assert run_thread_world(nranks, worker) == [1] * nranks
    reader = TraceReader(td, mode="stitched")
    assert reader.nranks == nranks
    assert reader.n_records(0) == 25 and reader.n_records(1) == 5
